"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060], TPU-adapted.

The selective state-space recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t      (state: (N, P) per head)
    y_t = C_t . h_t + D * x_t

is computed with the SSD *chunked* algorithm: sequences are split into
chunks of Q tokens; within a chunk the contribution is an attention-like
masked matmul (MXU-friendly), across chunks a short ``lax.scan`` carries the
(B, H, N, P) state.  This is the paper's (Dao & Gu) blocked duality mapped
onto jnp einsums -- no Triton port, the TPU gets big dense matmuls.

Projections are kept separate (wz/wx/wB/wC/wdt) rather than fused so tensor
parallelism can shard d_inner cleanly; depthwise causal convs (width 4) run
over the x/B/C streams as in the reference implementation.

Decode is the O(1) recurrence with a conv tail cache -- no attention, no KV
cache, which is why mamba2/jamba run the long_500k shape natively.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import rmsnorm


def mamba_params(make, prefix: str, *, d_model: int, d_inner: int,
                 ssm_state: int, num_heads: int, conv_width: int = 4):
    return {
        "wz": make(f"{prefix}.wz", (d_model, d_inner), P(None, "model")),
        "wx": make(f"{prefix}.wx", (d_model, d_inner), P(None, "model")),
        "wB": make(f"{prefix}.wB", (d_model, ssm_state), P(None, None)),
        "wC": make(f"{prefix}.wC", (d_model, ssm_state), P(None, None)),
        "wdt": make(f"{prefix}.wdt", (d_model, num_heads), P(None, None)),
        "conv_x": make(f"{prefix}.conv_x", (conv_width, d_inner), P(None, "model"), ("normal", 0.1)),
        "conv_B": make(f"{prefix}.conv_B", (conv_width, ssm_state), P(None, None), ("normal", 0.1)),
        "conv_C": make(f"{prefix}.conv_C", (conv_width, ssm_state), P(None, None), ("normal", 0.1)),
        "A_log": make(f"{prefix}.A_log", (num_heads,), P(None), "zeros"),
        "D": make(f"{prefix}.D", (num_heads,), P(None), "ones"),
        "dt_bias": make(f"{prefix}.dt_bias", (num_heads,), P(None), "zeros"),
        "norm": make(f"{prefix}.norm", (d_inner,), P("model"), "ones"),
        "out": make(f"{prefix}.out", (d_inner, d_model), P("model", None)),
    }


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: (B, L, C); kernel: (W, C)."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(w):
        out = out + xp[:, i : i + x.shape[1]].astype(jnp.float32) * kernel[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _ssd_chunked(x, b_in, c_in, dt, a, *, chunk: int,
                 h0: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x: (B, L, H, P); b_in/c_in: (B, L, N); dt: (B, L, H) (>0); a: (H,) (<0).
    Returns y: (B, L, H, P) and final state (B, H, N, P).
    """
    bsz, l, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q
    xs = x.reshape(bsz, nc, q, h, p)
    bs = b_in.reshape(bsz, nc, q, n)
    cs = c_in.reshape(bsz, nc, q, n)
    dts = dt.reshape(bsz, nc, q, h).astype(jnp.float32)

    da = dts * a  # (B, nc, Q, H)   (negative)
    cum = jnp.cumsum(da, axis=2)
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)        # decay from t to chunk end
    lam = jnp.exp(cum[:, :, -1, :])                    # (B, nc, H) whole-chunk decay

    # Per-chunk injected state: S_c = sum_i dec_end_i dt_i B_i (x) x_i.
    s_chunk = jnp.einsum("bcqh,bcqn,bcqhp->bchnp",
                         dec_end * dts, bs.astype(jnp.float32), xs.astype(jnp.float32))

    def scan_body(hprev, inp):
        lam_c, s_c = inp  # (B, H), (B, H, N, P)
        return lam_c[..., None, None] * hprev + s_c, hprev

    h_init = h0 if h0 is not None else jnp.zeros((bsz, h, n, p), jnp.float32)
    h_last, h_enter = jax.lax.scan(
        scan_body, h_init,
        (lam.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (B, nc, H, N, P): state entering chunk

    # Intra-chunk (masked attention-like) term.
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qt,Qi,H) = cum_t - cum_i
    mask = jnp.tril(jnp.ones((q, q), bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcqn,bcin->bcqi", cs.astype(jnp.float32), bs.astype(jnp.float32))
    scores = scores[..., None] * gate * dts[:, :, None, :, :]  # (B,nc,Qt,Qi,H)
    y_intra = jnp.einsum("bcqih,bcihp->bcqhp", scores, xs.astype(jnp.float32))

    # Inter-chunk term: y_inter(t) = exp(cum_t) * C_t . h_enter.
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp",
                         cs.astype(jnp.float32), h_enter, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), h_last


def ssd_reference(x, b_in, c_in, dt, a):
    """Naive O(L) recurrence oracle (tests only)."""
    bsz, l, h, p = x.shape
    n = b_in.shape[-1]

    def step(hprev, t):
        da = dt[:, t] * a  # (B, H)
        hnew = jnp.exp(da)[..., None, None] * hprev + jnp.einsum(
            "bh,bn,bhp->bhnp", dt[:, t], b_in[:, t], x[:, t])
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, t], hnew)
        return hnew, y

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    hfin, ys = jax.lax.scan(step, h0, jnp.arange(l))
    return ys.transpose(1, 0, 2, 3), hfin


def mamba_block(params, x, *, num_heads: int, head_dim: int, ssm_state: int,
                chunk: int = 256, return_state: bool = False):
    """Full-sequence mamba2 block.  x: (B, L, D)."""
    bsz, l, d = x.shape
    z = x @ params["wz"]
    x_raw = x @ params["wx"]
    b_raw = x @ params["wB"]
    c_raw = x @ params["wC"]
    xin = jax.nn.silu(_causal_conv(x_raw, params["conv_x"]))
    b_in = jax.nn.silu(_causal_conv(b_raw, params["conv_B"]))
    c_in = jax.nn.silu(_causal_conv(c_raw, params["conv_C"]))
    dt = jax.nn.softplus((x @ params["wdt"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, l, num_heads, head_dim)
    y, h_last = _ssd_chunked(xh, b_in, c_in, dt, a, chunk=chunk)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, l, num_heads * head_dim).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = y @ params["out"]
    if return_state:
        w = params["conv_x"].shape[0]
        tail = lambda r: r[:, -(w - 1):] if l >= w - 1 else jnp.pad(r, ((0, 0), (w - 1 - l, 0), (0, 0)))
        state = {"h": h_last, "conv_x": tail(x_raw), "conv_B": tail(b_raw),
                 "conv_C": tail(c_raw)}
        return out, state
    return out


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def init_mamba_cache(batch: int, *, num_heads: int, head_dim: int,
                     ssm_state: int, conv_width: int = 4, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, num_heads, ssm_state, head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, conv_width - 1, num_heads * head_dim), dtype),
        "conv_B": jnp.zeros((batch, conv_width - 1, ssm_state), dtype),
        "conv_C": jnp.zeros((batch, conv_width - 1, ssm_state), dtype),
    }


def _conv_step(cache_tail, new, kernel):
    """cache_tail: (B, W-1, C); new: (B, C). Returns (out (B,C), new_tail)."""
    full = jnp.concatenate([cache_tail, new[:, None]], axis=1)  # (B, W, C)
    out = jnp.sum(full.astype(jnp.float32) * kernel[None].astype(jnp.float32), axis=1)
    return out.astype(new.dtype), full[:, 1:]


def mamba_decode_step(params, x, cache, *, num_heads: int, head_dim: int,
                      ssm_state: int) -> tuple[jnp.ndarray, dict]:
    """One-token decode.  x: (B, 1, D)."""
    bsz = x.shape[0]
    xt = x[:, 0]
    z = xt @ params["wz"]
    xin_raw = xt @ params["wx"]
    b_raw = xt @ params["wB"]
    c_raw = xt @ params["wC"]
    xin, tail_x = _conv_step(cache["conv_x"], xin_raw, params["conv_x"])
    b_in, tail_b = _conv_step(cache["conv_B"], b_raw, params["conv_B"])
    c_in, tail_c = _conv_step(cache["conv_C"], c_raw, params["conv_C"])
    xin = jax.nn.silu(xin)
    b_in = jax.nn.silu(b_in).astype(jnp.float32)
    c_in = jax.nn.silu(c_in).astype(jnp.float32)
    dt = jax.nn.softplus((xt @ params["wdt"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(bsz, num_heads, head_dim).astype(jnp.float32)
    h = cache["h"]
    h = jnp.exp(dt * a)[..., None, None] * h + jnp.einsum("bh,bn,bhp->bhnp", dt, b_in, xh)
    y = jnp.einsum("bn,bhnp->bhp", c_in, h)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, num_heads * head_dim).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = (y @ params["out"])[:, None]
    return out, {"h": h, "conv_x": tail_x, "conv_B": tail_b, "conv_C": tail_c}
