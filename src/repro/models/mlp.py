"""Dense FFN variants: gated (SwiGLU/GeGLU) and plain (GELU / squared-ReLU).

Nemotron-4 uses squared-ReLU without gating [arXiv:2402.16819]; the Llama/
Mistral/Qwen family uses SwiGLU; Whisper uses GELU.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ACTIVATIONS

GATED = {"swiglu": "silu", "geglu": "gelu"}


def mlp_params(make, prefix: str, *, d_model: int, d_ff: int, activation: str):
    p = {"w_in": make(f"{prefix}.w_in", (d_model, d_ff), P(None, "model")),
         "w_out": make(f"{prefix}.w_out", (d_ff, d_model), P("model", None))}
    if activation in GATED:
        p["w_gate"] = make(f"{prefix}.w_gate", (d_model, d_ff), P(None, "model"))
    return p


def mlp(params, x, *, activation: str) -> jnp.ndarray:
    if activation in GATED:
        act = ACTIVATIONS[GATED[activation]]
        h = act(x @ params["w_gate"]) * (x @ params["w_in"])
    else:
        h = ACTIVATIONS[activation](x @ params["w_in"])
    return h @ params["w_out"]
