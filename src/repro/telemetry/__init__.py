"""Run telemetry: in-graph aggregation diagnostics + structured run logging.

Three pieces (DESIGN.md Sec. 11):

- :mod:`repro.telemetry.diagnostics` — the fixed-shape ``AggDiagnostics``
  struct every flat/masked/sharded engine can emit alongside its aggregate
  (``diagnostics=True``), computed inside the compiled step.
- :mod:`repro.telemetry.metrics` — the shared scalar-metric helpers
  (``honest_variance`` / ``consensus_dist`` / ``staleness_metrics``) all six
  step builders emit through.
- :mod:`repro.telemetry.runlogger` / :mod:`repro.telemetry.profiling` —
  the host-side JSONL sink (batched ``device_get``, never a per-step sync)
  and the per-phase wall-clock timers used by ``launch/train.py``.

Import discipline: these modules are imported BY ``repro.core`` (the
aggregators build diagnostics structs), so nothing here may import
``repro.core`` — only jax/numpy and ``repro.compat``.
"""
from repro.telemetry.diagnostics import (AggDiagnostics, diagnostics_metrics,
                                         flat_diagnostics, masked_diagnostics,
                                         reduce_masked_diagnostics)
from repro.telemetry.metrics import (consensus_dist, health_metrics,
                                     honest_variance, staleness_metrics)
from repro.telemetry.profiling import PhaseTimer
from repro.telemetry.runlogger import RunLogger

__all__ = [
    "AggDiagnostics",
    "PhaseTimer",
    "RunLogger",
    "consensus_dist",
    "diagnostics_metrics",
    "flat_diagnostics",
    "health_metrics",
    "honest_variance",
    "masked_diagnostics",
    "reduce_masked_diagnostics",
    "staleness_metrics",
]
