"""Buffered JSONL run logging (DESIGN.md Sec. 11).

``RunLogger`` is the host-side sink of the telemetry subsystem: the train
loop hands it the step's (still-on-device) metrics dict and moves on --
references are buffered and materialized with ONE batched
``jax.device_get`` per flush, so the hot loop never blocks on a per-step
device->host sync (the ``float(metrics[...])`` anti-pattern this replaces).

Layout of a run directory::

    runs/<name>/metrics.jsonl   one JSON object per logged step
    runs/<name>/meta.json       config + jax/mesh facts + HLO cost analysis
    runs/<name>/profile/        profiler trace (``--profile-steps``,
                                ``repro.compat.profiler_trace``)

With ``log_dir=None`` the logger is console-only: the same buffered
batching drives the progress line, nothing is written to disk.
"""
from __future__ import annotations

import atexit
import json
import os
from typing import Any, Callable, Optional

import jax
import numpy as np


def _jsonable(x):
    if isinstance(x, (np.ndarray, np.generic)):
        if x.ndim == 0:
            return x.item()
        return np.asarray(x).tolist()
    return x


class RunLogger:
    """Buffered metrics sink: JSONL file + optional console line.

    ``log_every``: keep every N-th step (1 = all).  ``flush_every``: how
    many buffered rows trigger a batched ``device_get`` + write.
    ``console``: optional callback ``(step, row_dict) -> None`` invoked at
    flush time for the rows where ``console_every`` hits (the train loop's
    progress printing, moved off the hot path).  ``on_row``: optional
    callback ``(row_dict) -> None`` invoked for EVERY flushed row in step
    order -- the run-health monitor (``launch/health.py``) hangs off this,
    inheriting the batched device_get instead of adding its own syncs.

    Crash safety: a final flush is registered with ``atexit`` at
    construction (and unregistered on ``close``), so rows buffered when
    the process dies mid-run -- an exception in the train loop, a
    SystemExit -- still land in ``metrics.jsonl`` instead of evaporating
    with the buffer (tests/test_telemetry.py pins this).
    """

    def __init__(self, log_dir: Optional[str] = None, *, log_every: int = 1,
                 flush_every: int = 32,
                 console: Optional[Callable[[int, dict], None]] = None,
                 console_every: int = 0,
                 on_row: Optional[Callable[[dict], None]] = None):
        self.log_dir = log_dir
        self.log_every = max(int(log_every), 1)
        self.flush_every = max(int(flush_every), 1)
        self.console = console
        self.console_every = max(int(console_every), 0)
        self.on_row = on_row
        self._buf: list[tuple[int, dict, dict]] = []
        self._file = None
        if log_dir is not None:
            os.makedirs(log_dir, exist_ok=True)
            self._file = open(os.path.join(log_dir, "metrics.jsonl"), "w")
        # Flush-on-crash: close() unregisters; after close the buffer is
        # empty and the handle None, so a leftover registration is a no-op.
        atexit.register(self.close)

    # -- meta ---------------------------------------------------------------

    def write_meta(self, **fields: Any) -> None:
        """Write ``meta.json`` (config, jax version, mesh shape, HLO cost
        analysis...).  No-op in console-only mode."""
        if self.log_dir is None:
            return
        path = os.path.join(self.log_dir, "meta.json")
        with open(path, "w") as f:
            json.dump(fields, f, indent=2, sort_keys=True, default=str)
            f.write("\n")

    # -- metrics ------------------------------------------------------------

    def log_step(self, step: int, metrics: dict, host: Optional[dict] = None
                 ) -> None:
        """Buffer one step's metrics.  ``metrics`` values may be live device
        arrays -- they are NOT materialized here.  ``host`` carries values
        already on the host (phase timings, wall-clock)."""
        printing = self.console is not None and self.console_every and (
            step % self.console_every == 0)
        if step % self.log_every != 0 and not printing:
            return
        self._buf.append((step, dict(metrics), dict(host or {})))
        if len(self._buf) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        """One batched ``device_get`` over everything buffered, then write
        JSONL rows / emit console lines."""
        if not self._buf:
            return
        buf, self._buf = self._buf, []
        fetched = jax.device_get([m for _, m, _ in buf])
        for (step, _, host), metrics in zip(buf, fetched):
            row = {"step": step}
            row.update({k: _jsonable(v) for k, v in metrics.items()})
            row.update({k: _jsonable(v) for k, v in host.items()})
            if self._file is not None and step % self.log_every == 0:
                self._file.write(json.dumps(row) + "\n")
            if self.on_row is not None:
                self.on_row(row)
            if (self.console is not None and self.console_every
                    and step % self.console_every == 0):
                self.console(step, row)
        if self._file is not None:
            self._file.flush()

    def close(self) -> None:
        self.flush()
        if self._file is not None:
            self._file.close()
            self._file = None
        atexit.unregister(self.close)

    def __enter__(self) -> "RunLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
