"""In-graph aggregation diagnostics (DESIGN.md Sec. 11).

Every robust rule quietly computes a per-worker suspicion signal — geomed's
implicit Weiszfeld weights, krum's scores, centered-clip's clip scales — and
then throws it away.  ``AggDiagnostics`` is the small fixed-shape struct the
engines return alongside the aggregate when called with ``diagnostics=True``:
it rides the compiled step as extra outputs (no host sync, no recompilation
of the ``diagnostics=False`` path, which stays byte-identical to before).

The struct has the SAME fields for every rule so step builders can thread it
without per-rule plumbing; rules fill what they have and leave neutral
defaults elsewhere (``score`` zeros, ``selected`` -1, ``clip_frac`` 0,
``converged`` True for non-iterative rules).

Shapes: on the master path the leading axis is the worker slot ``(W,)``; on
the masked/decentralized path engines emit ``(R, S)`` receiver-by-sender
fields which :func:`reduce_masked_diagnostics` folds into a replicated
per-sender ``(S,)`` summary for the metrics dict.

Import discipline: imported by ``repro.core.aggregators`` — must not import
``repro.core`` (only jax + ``repro.compat``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat

# Matches core.geomed._DIST_FLOOR: guards the inverse-distance weights.
_FLOOR = 1e-8


class AggDiagnostics(NamedTuple):
    """Fixed-shape per-round aggregation diagnostics.

    ``weight`` is the rule's implicit per-worker weight (normalized to sum
    to 1): inverse distance-to-aggregate for the geomed family (times any
    staleness ``row_weights``), a one-hot of the winner for krum, the
    normalized clip scales for centered_clip, the (staleness-weighted)
    uniform weights for mean.  It is the Byzantine-suspicion trace the
    tests and the JSONL log pin: attacked slots rank low.
    """

    dist: jax.Array       # (W,) | (R, S) f32 distance of each message to the aggregate
    weight: jax.Array     # (W,) | (R, S) f32 implicit weight, sums to 1
    score: jax.Array      # (W,) | (R, S) f32 krum scores (zeros for other rules)
    selected: jax.Array   # () | (R,) int32 krum argmin; -1 for other rules
    clip_frac: jax.Array  # () f32 fraction of live rows clipped (centered_clip)
    residual: jax.Array   # () f32 final Weiszfeld step size (geomed family)
    iters: jax.Array      # () int32 Weiszfeld iterations run
    converged: jax.Array  # () bool (True for non-iterative rules)


def _psum_all(x, axis_names):
    for ax in axis_names:
        x = compat.psum(x, ax)
    return x


def _normalize(w):
    return w / jnp.maximum(jnp.sum(w), _FLOOR)


def flat_diagnostics(buf, agg, *, row_weights=None, axis_names=(),
                     weight=None, score=None, selected=None, clip_frac=None,
                     residual=None, iters=None, converged=None):
    """Build ``AggDiagnostics`` for a flat ``(W, D)`` round.

    ``axis_names`` are the mesh axes the coordinate dimension is sharded
    over (the sharded path passes its comm axes): per-row squared distances
    are partial on each device and psum'd so the struct is replicated.
    Rule-specific fields are keyword overrides; everything else gets the
    generic inverse-distance treatment (exactly the Weiszfeld implicit
    weight ``rw / max(dist, floor)``, normalized).
    """
    b32 = buf.astype(jnp.float32)
    d = b32 - agg.astype(jnp.float32)[None, :]
    sq = _psum_all(jnp.sum(d * d, axis=-1), axis_names)
    dist = jnp.sqrt(sq)
    if weight is None:
        rw = (jnp.ones((buf.shape[0],), jnp.float32) if row_weights is None
              else row_weights.astype(jnp.float32))
        weight = rw / jnp.maximum(dist, _FLOOR)
    return AggDiagnostics(
        dist=dist,
        weight=_normalize(weight),
        score=jnp.zeros_like(dist) if score is None else score.astype(jnp.float32),
        selected=(jnp.int32(-1) if selected is None
                  else jnp.asarray(selected, jnp.int32)),
        clip_frac=(jnp.float32(0.0) if clip_frac is None
                   else jnp.asarray(clip_frac, jnp.float32)),
        residual=(jnp.float32(0.0) if residual is None
                  else jnp.asarray(residual, jnp.float32)),
        iters=jnp.int32(0) if iters is None else jnp.asarray(iters, jnp.int32),
        converged=(jnp.bool_(True) if converged is None
                   else jnp.asarray(converged, jnp.bool_)),
    )


def masked_diagnostics(exchange, out, mask, *, axis_names=(),
                       score=None, selected=None, clip_frac=None,
                       residual=None, iters=None, converged=None):
    """Build ``AggDiagnostics`` for a masked ``(R, S, D)`` exchange.

    ``mask`` is the (possibly staleness-weighted) receiver-by-sender weight
    matrix the masked engines consumed; dead edges (mask 0) get weight and
    distance exactly 0.  ``dist``/``weight``/``score`` keep the (R, S)
    shape; ``selected`` is per-receiver (R,); the scalars summarize the
    whole exchange.  Coordinate partials are psum'd over ``axis_names``
    (the decentralized gather path hands model-sharded slices).
    """
    e32 = exchange.astype(jnp.float32)
    d = e32 - out.astype(jnp.float32)[:, None, :]
    sq = _psum_all(jnp.sum(d * d, axis=-1), axis_names)
    live = (mask > 0).astype(jnp.float32)
    dist = jnp.sqrt(sq) * live
    inv = mask.astype(jnp.float32) / jnp.maximum(jnp.sqrt(sq), _FLOOR)
    weight = inv / jnp.maximum(jnp.sum(inv, axis=1, keepdims=True), _FLOOR)
    return AggDiagnostics(
        dist=dist,
        weight=weight,
        score=jnp.zeros_like(dist) if score is None else score.astype(jnp.float32),
        selected=(-jnp.ones((mask.shape[0],), jnp.int32) if selected is None
                  else jnp.asarray(selected, jnp.int32)),
        clip_frac=(jnp.float32(0.0) if clip_frac is None
                   else jnp.asarray(clip_frac, jnp.float32)),
        residual=(jnp.float32(0.0) if residual is None
                  else jnp.asarray(residual, jnp.float32)),
        iters=jnp.int32(0) if iters is None else jnp.asarray(iters, jnp.int32),
        converged=(jnp.bool_(True) if converged is None
                   else jnp.asarray(converged, jnp.bool_)),
    )


def reduce_masked_diagnostics(diag, mask, *, axis_names=()):
    """Fold ``(R, S)`` masked diagnostics into a per-sender ``(S,)`` summary.

    Receiver rows may live on different devices (the distributed gather
    path holds one receiver row per device), so every cross-receiver sum
    goes through ``psum`` over ``axis_names``; the result is replicated.
    Per-sender ``dist``/``score`` are means over the receivers that hear
    the sender; ``weight`` is the total weight a sender received,
    renormalized; ``selected`` is the most frequently krum-selected sender
    (-1 when the rule never selects).
    """
    live = (mask > 0).astype(jnp.float32)
    num_senders = mask.shape[1]

    def rsum(x):
        return _psum_all(jnp.sum(x, axis=0), axis_names)

    cnt = jnp.maximum(rsum(live), 1.0)
    dist = rsum(diag.dist * live) / cnt
    wsum = rsum(diag.weight * live)
    weight = _normalize(wsum)
    score = rsum(diag.score * live) / cnt
    sel_counts = rsum(jax.nn.one_hot(diag.selected, num_senders,
                                     dtype=jnp.float32))
    selected = jnp.where(jnp.sum(sel_counts) > 0,
                         jnp.argmax(sel_counts).astype(jnp.int32),
                         jnp.int32(-1))
    nrec = rsum(jnp.ones((mask.shape[0],), jnp.float32))

    def rmean(x):  # mean over receivers of a per-call scalar
        return rsum(jnp.broadcast_to(x, (mask.shape[0],))) / nrec

    conv = rmean(diag.converged.astype(jnp.float32))
    return AggDiagnostics(
        dist=dist, weight=weight, score=score, selected=selected,
        clip_frac=rmean(diag.clip_frac),
        residual=rmean(diag.residual),
        iters=rmean(diag.iters.astype(jnp.float32)).astype(jnp.int32),
        converged=conv >= 1.0 - 1e-6,
    )


def diagnostics_metrics(diag, prefix="diag_"):
    """Flatten the struct into ``{"diag_dist": ..., ...}`` metric entries."""
    return {prefix + k: v for k, v in diag._asdict().items()}
