"""Per-phase wall-clock timers for the train loop (DESIGN.md Sec. 11).

``PhaseTimer`` accumulates host-side wall time per named phase (``data`` /
``step`` / ``host`` in ``launch/train.py``) between snapshots.  Note the
dispatch caveat: jax returns control before device work finishes, so the
``step`` phase measures dispatch+blocking only when something downstream
synchronizes -- the hardware truth lives in the ``--profile-steps``
profiler trace (``repro.compat.profiler_trace``), these timers are the
cheap always-on complement.
"""
from __future__ import annotations

import time
from contextlib import contextmanager


class PhaseTimer:
    """Accumulate wall-clock seconds per phase; ``snapshot()`` drains."""

    def __init__(self):
        self._acc: dict[str, float] = {}

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._acc[name] = (self._acc.get(name, 0.0)
                               + time.perf_counter() - t0)

    def snapshot(self) -> dict[str, float]:
        """``{"time_<phase>_s": seconds}`` accumulated since the last
        snapshot, then reset."""
        out = {f"time_{k}_s": round(v, 6) for k, v in self._acc.items()}
        self._acc = {}
        return out
