"""Shared scalar-metric helpers for the six step builders (DESIGN.md Sec. 11).

``mean_staleness`` / ``honest_variance`` / ``consensus_dist`` used to be
re-derived ad hoc in ``core/robust_step.py``, ``topology/
decentralized_step.py`` and ``launch/steps.py``; every builder now emits
them through these three functions so the formulas (and their metric names)
cannot drift between execution paths.

Import discipline: pulled in by ``repro.core`` -- only jax here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_FLOOR = 1e-8


def honest_variance(honest, num_honest: int) -> jnp.ndarray:
    """Mean squared deviation of the honest messages around their mean
    (the paper's bottom-row variance curves): ``sum_w ||z_w - z_bar||^2 / W_h``.

    ``honest``: the packed ``(W_h, D)`` buffer, or a pytree whose leaves
    carry a leading ``(W_h,)`` worker axis (the per-leaf paths).  Both forms
    keep the exact op order of the pre-telemetry inline code, so packed vs
    per-leaf trajectory pins are unaffected.
    """
    if isinstance(honest, jnp.ndarray):
        h32 = honest.astype(jnp.float32)
        return jnp.sum((h32 - jnp.mean(h32, axis=0)[None]) ** 2) / num_honest
    hm = jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0), honest)
    return sum(
        jnp.sum((z.astype(jnp.float32) - m.astype(jnp.float32)[None]) ** 2)
        for z, m in zip(jax.tree_util.tree_leaves(honest),
                        jax.tree_util.tree_leaves(hm))
    ) / num_honest


def consensus_dist(params, honest_mask: jnp.ndarray,
                   num_honest: int) -> jnp.ndarray:
    """Honest-node consensus drift of a decentralized parameter state:
    mean squared distance of each honest node's model to the honest mean.

    ``params``: pytree with a leading ``(N,)`` node axis on every leaf.
    ``honest_mask``: ``(N,)`` 0/1 selector of the honest nodes -- mask-
    select, never a slice of the (possibly mesh-sharded) node axis
    (the old-XLA hazard, DESIGN.md Sec. 1).
    """
    mask = honest_mask.astype(jnp.float32)
    cons = jnp.float32(0.0)
    for x in jax.tree_util.tree_leaves(params):
        x32 = x.reshape(x.shape[0], -1).astype(jnp.float32)
        m = jnp.sum(mask[:, None] * x32, axis=0) / num_honest
        cons = cons + jnp.sum(mask[:, None] * (x32 - m[None]) ** 2)
    return cons / num_honest


def health_metrics(health, accepted) -> dict:
    """Round-health scalars from the guards verdict (DESIGN.md Sec. 13).

    ``health``: the ``(4,)`` ``[ema, ema_sq, rejected, seen]`` vector
    carried in the train state (``repro.core.guards``), or ``None`` when
    guards are off -- returns ``{}`` so the metric keys only appear on
    guarded runs (same conditional shape as ``staleness_metrics``).
    ``accepted``: this round's scalar verdict (1.0 accept / 0.0 reject).
    """
    if health is None:
        return {}
    return {
        "round_accepted": accepted.astype(jnp.float32),
        "rejected_rounds": health[2],
        "agg_norm_ema": health[0],
    }


def staleness_metrics(slot_staleness) -> dict:
    """``{"mean_staleness": ...}`` from the round's per-slot staleness
    counters, or ``{}`` under full participation (``None``) -- the one
    conditional all six builders share."""
    if slot_staleness is None:
        return {}
    return {"mean_staleness": jnp.mean(slot_staleness.astype(jnp.float32))}
